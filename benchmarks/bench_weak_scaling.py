"""Paper Fig 9: weak scaling of banded multiply and symmetric square.

Runtime-simulator (repro.runtime.scheduler) wall time for matrix dimension
proportional to worker count; the symmetric square should retain its ~2x
advantage at every scale, and wall time should grow only polylog (eq (14)):
the critical-path column is the Tinf term of Brent's bound, the work
column the T1/p term.  CSV on stdout; ``--out FILE`` writes JSON.
CSV: op,workers,N,wall_s,gflop,speedup_vs_multiply,parallel_eff,
critical_path_ms,brent_bound_s.
"""
import argparse
import pathlib

from repro import Session
from repro.core import analysis as an
from repro.core.patterns import banded_mask, values_for_mask

try:
    from benchmarks._artifact import write_artifact
except ImportError:                     # run directly from benchmarks/
    from _artifact import write_artifact


def run(op, workers, n_per, d, leaf_n, bs):
    n = n_per * workers
    a = values_for_mask(banded_mask(n, d), seed=1, symmetric=True)
    sess = Session(leaf_n=leaf_n, bs=bs, p=workers, seed=0)
    if op == "multiply":
        A = sess.from_dense(a)
        B = sess.from_dense(a)
        sess.simulate()
        _ = A @ B
    else:
        S = sess.from_dense(a, upper=True)
        sess.simulate()
        _ = S.sym_square()
    rep = sess.simulate(fresh_stats=True)
    return rep, sess.flops, n


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", type=pathlib.Path, default=None)
    args = ap.parse_args()

    print("op,workers,N,wall_s,gflop,speedup_vs_multiply,parallel_eff,"
          "critical_path_ms,brent_bound_s")
    n_per, d = 256, 24
    walls = {}
    records = []
    for op in ("multiply", "sym_square"):
        for workers in (1, 2, 4, 8):
            rep, fl, n = run(op, workers, n_per, d, 64, 8)
            walls[(op, workers)] = rep.makespan
            speed = walls[("multiply", workers)] / rep.makespan \
                if op == "sym_square" else 1.0
            cp = an.critical_path_summary(rep.crit.work_s, rep.crit.length_s,
                                          workers, rep.makespan)
            rec = {"op": op, "workers": workers, "n": n,
                   "wall_s": rep.makespan, "gflop": fl / 1e9,
                   "speedup_vs_multiply": speed, "steals": rep.steals,
                   **cp}
            records.append(rec)
            print(f"{op},{workers},{n},{rep.makespan:.4f},{fl / 1e9:.3f},"
                  f"{speed:.2f},{cp['parallel_efficiency']:.2f},"
                  f"{cp['critical_path_s'] * 1e3:.2f},"
                  f"{cp['brent_bound_s']:.4f}", flush=True)
    if args.out:
        write_artifact(args.out, "weak_scaling", {"records": records},
                       params={"n_per": n_per, "d": d,
                               "workers": [1, 2, 4, 8]})
        print(f"wrote {args.out}")

    # symmetric square clearly faster (paper Fig 9 right; its ~2x flop
    # advantage is partly eaten by top-of-tree serialization at this size)
    sp = walls[("multiply", 8)] / walls[("sym_square", 8)]
    assert sp > 1.25, f"sym square speedup only {sp:.2f}"
    # weak scaling: wall time grows far slower than the 8x work growth
    growth = walls[("multiply", 8)] / walls[("multiply", 1)]
    assert growth < 4.0, f"weak scaling wall grew {growth:.2f}x"
    # Brent's bound sanity: the greedy schedule can never beat it
    for rec in records:
        assert rec["wall_s"] >= rec["brent_bound_s"] * (1 - 1e-9), rec


if __name__ == "__main__":
    main()
