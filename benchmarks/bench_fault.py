"""Failure rate x recovery policy sweep on the fault-tolerant scheduler.

    PYTHONPATH=src python benchmarks/bench_fault.py [--quick] \
        [--out BENCH_fault.json]

Injects deterministic worker deaths into the simulated multiply phase
(DESIGN.md §10) on two structure patterns — a banded matrix product and
the S^2 overlap-matrix square — and sweeps the number of failures (0-2)
against the three recovery policies:

* ``lineage``  — recompute the minimal producer closure of the lost
  chunks (the Chunks-and-Tasks claim);
* ``replication`` — r=2 copies at registration, deaths re-point at
  survivors;
* ``none``     — no fault tolerance: a death restarts the whole phase
  (the plain-SPMD baseline).

The artifact (``BENCH_fault.json``) carries one row per (pattern,
policy, n_failures): makespan, degradation vs fault-free, tasks
recomputed, chunks lost/recovered, bytes re-replicated.  The bench
asserts the PR's acceptance claims on the banded pattern:

1. lineage keeps makespan degradation < 2x fault-free at 1-2 failures,
   and ``tasks_recomputed`` is a strict subset of the phase DAG;
2. lineage recompute beats the full re-run (fewer recomputed tasks and
   no worse makespan than ``none``);
3. replication bounds recompute work (zero recomputed tasks after a
   single failure, at the price of re-replication bytes).

Results are exact, not sampled: the simulator is deterministic, so every
row is reproducible bit-for-bit from (pattern, schedule, policy).
"""
from __future__ import annotations

import argparse
import pathlib
import sys

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
from _artifact import write_artifact  # noqa: E402

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
from repro import Session  # noqa: E402
from repro.core.patterns import (banded_mask, divide_space_order,  # noqa: E402
                                 overlap_pairs, particle_cloud,
                                 values_for_mask)
from repro.runtime.recovery import FaultSchedule, kill  # noqa: E402

P = 8            # simulated workers
REPLICAS = 2
# kill times as fractions of the fault-free makespan: mid-phase deaths
# are the expensive ones (plenty of placed chunks, plenty of work left)
KILL_AT = (0.45, 0.7)
KILL_WORKERS = (2, 5)


def _build_banded(n: int, d: int, policy: str):
    a = values_for_mask(banded_mask(n, d), seed=1, symmetric=True)
    b = values_for_mask(banded_mask(n, d), seed=2, symmetric=True)
    sess = Session(leaf_n=max(n // 8, 32), bs=8, p=P, seed=0)
    A, B = sess.from_dense(a), sess.from_dense(b)
    sess.simulate(faults=_build_faults(policy))
    return sess, A @ B


def _build_s2(n_per: int, policy: str):
    coords = particle_cloud(n_per, 3, seed=3)
    order = divide_space_order(coords)
    rows, cols = overlap_pairs(coords, 4.0, order=order)
    n = 1 << int(np.ceil(np.log2(len(coords))))
    sess = Session(leaf_n=max(n // 16, 32), bs=8, p=P, seed=0)
    S = sess.from_pattern(rows, cols, n, upper=True)
    sess.simulate(faults=_build_faults(policy))
    return sess, S.sym_square()


def _build_faults(policy: str):
    """Replication must already hold during the build phase so the input
    matrices have copies when the multiply-phase death hits."""
    if policy != "replication":
        return None
    return FaultSchedule(events=[], recovery="replication",
                         replicas=REPLICAS)


def _schedule(policy: str, n_failures: int, m0: float):
    events = [kill(frac * m0, w)
              for frac, w in zip(KILL_AT[:n_failures],
                                 KILL_WORKERS[:n_failures])]
    return FaultSchedule(events=events, recovery=policy, replicas=REPLICAS)


def sweep_pattern(name: str, build, quick: bool) -> list:
    """All (policy, n_failures) cells for one structure pattern."""
    sess0, C0 = build("lineage")
    rep0 = sess0.simulate(fresh_stats=True)
    m0, n_tasks = rep0.makespan, rep0.n_tasks
    dense0 = C0.to_dense()
    rows = [{
        "pattern": name, "policy": "fault-free", "n_failures": 0,
        "makespan": m0, "degradation": 1.0, "n_tasks": n_tasks,
        "tasks_recomputed": 0, "chunks_lost": 0, "chunks_recovered": 0,
        "bytes_rereplicated": 0,
    }]
    failures = (1,) if quick else (1, 2)
    for policy in ("lineage", "replication", "none"):
        for k in failures:
            sess, C = build(policy)
            rep = sess.simulate(fresh_stats=True,
                                faults=_schedule(policy, k, m0))
            assert np.array_equal(C.to_dense(), dense0), \
                f"{name}/{policy}/k={k}: result diverged from fault-free"
            rows.append({
                "pattern": name, "policy": policy, "n_failures": k,
                "makespan": rep.makespan,
                "degradation": rep.makespan / m0,
                "n_tasks": n_tasks,
                "tasks_recomputed": rep.tasks_recomputed,
                "chunks_lost": rep.chunks_lost,
                "chunks_recovered": rep.chunks_recovered,
                "bytes_rereplicated": rep.bytes_rereplicated,
            })
            print(f"{name:>7s} {policy:>11s} k={k}: "
                  f"deg={rep.makespan / m0:5.2f}x "
                  f"recomputed={rep.tasks_recomputed}/{n_tasks} "
                  f"lost={rep.chunks_lost} "
                  f"rerep={rep.bytes_rereplicated}", flush=True)
    return rows


def check_claims(rows: list) -> None:
    """The PR's acceptance criteria, on the banded pattern."""
    by = {(r["policy"], r["n_failures"]): r for r in rows
          if r["pattern"] == "banded"}
    for (policy, k), r in by.items():
        if policy == "fault-free":
            continue
        # a real recovery policy never recomputes more than the DAG; the
        # "none" baseline can (its restarted work restarts again on the
        # second death) — that being possible is exactly why it is bad
        if policy != "none":
            assert r["tasks_recomputed"] <= r["n_tasks"], (policy, k)
        if policy == "lineage":
            assert r["degradation"] < 2.0, \
                f"lineage k={k}: degradation {r['degradation']:.2f} >= 2x"
            assert 0 < r["tasks_recomputed"] < r["n_tasks"], \
                f"lineage k={k}: closure not a strict subset of the DAG"
        none = by.get(("none", k))
        lin = by.get(("lineage", k))
        if none and lin:
            assert lin["tasks_recomputed"] < none["tasks_recomputed"], \
                f"k={k}: lineage did not beat the full re-run"
            assert lin["makespan"] <= none["makespan"], \
                f"k={k}: lineage makespan worse than restart-from-scratch"
    rep1 = by.get(("replication", 1))
    assert rep1 and rep1["tasks_recomputed"] == 0, \
        "replication r=2 must absorb a single failure with zero recompute"
    assert rep1["bytes_rereplicated"] > 0, \
        "replication must restore the factor after a death"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized: smaller operands, single-failure only")
    ap.add_argument("--out", default=None, help="artifact path")
    args = ap.parse_args()

    n, d = (256, 12) if args.quick else (512, 24)
    n_per = 8 if args.quick else 10
    rows = sweep_pattern("banded",
                         lambda pol: _build_banded(n, d, pol), args.quick)
    rows += sweep_pattern("s2",
                          lambda pol: _build_s2(n_per, pol), args.quick)
    check_claims(rows)
    print(f"\nall fault-recovery claims hold on {len(rows)} cells")

    if args.out:
        path = write_artifact(
            args.out, "fault", {"rows": rows},
            params={"quick": args.quick, "p": P, "replicas": REPLICAS,
                    "n": n, "band": d, "s2_n_per": n_per,
                    "kill_at": list(KILL_AT),
                    "kill_workers": list(KILL_WORKERS)})
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
