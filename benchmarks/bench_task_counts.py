"""Paper Figs 3-4: multiplication-task counts per quadtree level.

Empirical counts from coordinate lists vs the closed-form bounds
(eqs (1)-(3), (8)-(12)).  CSV: pattern,level,count,bound.

``--facade-overhead`` instead times task-graph *construction* through the
Session/Matrix facade against the direct ``qt_*`` free-function layer it
compiles to, asserts the facade adds <5% overhead and that both register
the identical graph, and writes a JSON record alongside the other bench
outputs.
"""
import argparse
import json
import pathlib
import time

import numpy as np

from repro.core import analysis as an
from repro.core.patterns import (banded_mask, banded_pairs,
                                 divide_space_order, overlap_pairs,
                                 particle_cloud, random_mask, rmat_pairs,
                                 values_for_mask)


def facade_overhead(n=1024, d=48, leaf_n=64, bs=8, repeats=15):
    """Graph-construction wall time: Session/Matrix vs direct qt_* calls.

    The facade is a thin compiler onto the free functions — a handful of
    attribute lookups per whole-matrix operation, nothing per task — so
    its overhead must stay in the noise (<5% on min-of-N timings).
    """
    from repro import Session
    from repro.core.multiply import qt_multiply
    from repro.core.quadtree import QTParams, qt_from_dense
    from repro.core.tasks import CTGraph

    a = values_for_mask(banded_mask(n, d), seed=1)
    params = QTParams(n, leaf_n, bs)

    def direct():
        g = CTGraph()
        ra = qt_from_dense(g, a, params)
        rb = qt_from_dense(g, a, params)
        qt_multiply(g, params, ra, rb)
        return g

    def facade():
        sess = Session(leaf_n=leaf_n, bs=bs)
        A = sess.from_dense(a)
        B = sess.from_dense(a)
        _ = A @ B
        return sess.graph

    # identical graph: the facade registers the exact same task program
    g_direct, g_facade = direct(), facade()
    assert g_direct.count_kinds() == g_facade.count_kinds(), \
        (g_direct.count_kinds(), g_facade.count_kinds())

    times = {"direct": [], "facade": []}
    pair = (("direct", direct), ("facade", facade))
    for r in range(repeats):
        # alternate order per repeat so drift hits both sides equally
        for name, fn in (pair if r % 2 == 0 else pair[::-1]):
            t0 = time.perf_counter()
            fn()
            times[name].append(time.perf_counter() - t0)
    t_direct, t_facade = min(times["direct"]), min(times["facade"])
    # the guard compares the *minima*: noise on a shared machine is purely
    # additive (contention, GC), so each min converges to that side's true
    # floor as repeats grow, and their ratio estimates the systematic cost
    ratios = sorted(f / d for d, f in zip(times["direct"],
                                          times["facade"]))
    return {
        "bench": "facade_overhead", "n": n, "d": d, "leaf_n": leaf_n,
        "bs": bs, "repeats": repeats, "tasks": len(g_direct.nodes),
        "direct_s": t_direct, "facade_s": t_facade,
        "overhead": t_facade / t_direct - 1.0,
        "overhead_median_pair": ratios[len(ratios) // 2] - 1.0,
        "direct_s_all": times["direct"], "facade_s_all": times["facade"],
    }


def run_facade_overhead(out: pathlib.Path) -> None:
    rec = facade_overhead()
    print(json.dumps({k: v for k, v in rec.items()
                      if not k.endswith("_all")}, indent=1, sort_keys=True))
    out.write_text(json.dumps(rec, indent=1, sort_keys=True))
    print(f"wrote {out}")
    assert rec["overhead"] < 0.05, \
        f"facade adds {rec['overhead'] * 100:.1f}% graph-construction " \
        f"overhead (budget: 5%)"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--facade-overhead", action="store_true",
                    help="time Session/Matrix vs direct qt_* graph "
                         "construction and assert <5%% overhead")
    ap.add_argument("--out", type=pathlib.Path,
                    default=pathlib.Path("BENCH_facade_overhead.json"),
                    help="JSON output path for --facade-overhead")
    args = ap.parse_args()
    if args.facade_overhead:
        run_facade_overhead(args.out)
        return

    print("pattern,level,count,bound")

    # Fig 3 left: random, L=10, ~65 nnz/row
    L = 10
    n = 1 << L
    rows, cols = np.nonzero(random_mask(n, 65.0 / n, seed=0))
    per = an.count_tasks_per_level_pairs(rows, cols, n)
    for lvl in sorted(per):
        bound = min(an.random_bound_low(lvl),
                    an.random_bound_high(L, 65.0 / n, lvl))
        print(f"random,{lvl},{per[lvl]},{bound:.0f}")
    total = sum(per.values())
    print(f"random,total,{total},{an.random_total_bound(n, 65.0 / n):.0f}")

    # Fig 3 right: banded, d = 2^k
    k = 5
    d = 1 << k
    rows, cols = banded_pairs(n, d)
    per = an.count_tasks_per_level_pairs(rows, cols, n)
    for lvl in sorted(per):
        print(f"banded,{lvl},{per[lvl]},"
              f"{an.banded_tasks_bound(L, k, lvl):.0f}")
    print(f"banded,total,{sum(per.values())},"
          f"{an.banded_total_bound(n, d):.0f}")

    # Fig 4 left: overlap matrices for 1d/2d/3d particle clouds
    for dim, n_per in ((1, 4096), (2, 64), (3, 16)):
        coords = particle_cloud(n_per, dim, seed=1)
        order = divide_space_order(coords)
        rows, cols = overlap_pairs(coords, 4.0, order=order)
        npart = len(coords)
        g = 1 << int(np.ceil(np.log2(npart)))
        per = an.count_tasks_per_level_pairs(rows, cols, g)
        leaf = per[max(per)]
        total = sum(per.values())
        print(f"overlap{dim}d,leaf,{leaf},")
        print(f"overlap{dim}d,total,{total},")
        # locality: total within small factor of leaf count (paper §5.1)
        assert total < 3.0 * leaf

    # Fig 4 right: R-MAT locality sweep
    for a in (0.25, 0.4, 0.6, 0.8, 0.95):
        rows, cols = rmat_pairs(10, 5.0, a, seed=2)
        per = an.count_tasks_per_level_pairs(rows, cols, 1 << 10)
        leaf = per[max(per)]
        total = sum(per.values())
        print(f"rmat_a{a},leaf,{leaf},")
        print(f"rmat_a{a},total,{total},")


if __name__ == "__main__":
    main()
