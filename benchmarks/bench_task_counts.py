"""Paper Figs 3-4: multiplication-task counts per quadtree level.

Empirical counts from coordinate lists vs the closed-form bounds
(eqs (1)-(3), (8)-(12)).  CSV: pattern,level,count,bound.
"""
import numpy as np

from repro.core import analysis as an
from repro.core.patterns import (banded_pairs, divide_space_order,
                                 overlap_pairs, particle_cloud, random_mask,
                                 rmat_pairs)


def main() -> None:
    print("pattern,level,count,bound")

    # Fig 3 left: random, L=10, ~65 nnz/row
    L = 10
    n = 1 << L
    rows, cols = np.nonzero(random_mask(n, 65.0 / n, seed=0))
    per = an.count_tasks_per_level_pairs(rows, cols, n)
    for lvl in sorted(per):
        bound = min(an.random_bound_low(lvl),
                    an.random_bound_high(L, 65.0 / n, lvl))
        print(f"random,{lvl},{per[lvl]},{bound:.0f}")
    total = sum(per.values())
    print(f"random,total,{total},{an.random_total_bound(n, 65.0 / n):.0f}")

    # Fig 3 right: banded, d = 2^k
    k = 5
    d = 1 << k
    rows, cols = banded_pairs(n, d)
    per = an.count_tasks_per_level_pairs(rows, cols, n)
    for lvl in sorted(per):
        print(f"banded,{lvl},{per[lvl]},"
              f"{an.banded_tasks_bound(L, k, lvl):.0f}")
    print(f"banded,total,{sum(per.values())},"
          f"{an.banded_total_bound(n, d):.0f}")

    # Fig 4 left: overlap matrices for 1d/2d/3d particle clouds
    for dim, n_per in ((1, 4096), (2, 64), (3, 16)):
        coords = particle_cloud(n_per, dim, seed=1)
        order = divide_space_order(coords)
        rows, cols = overlap_pairs(coords, 4.0, order=order)
        npart = len(coords)
        g = 1 << int(np.ceil(np.log2(npart)))
        per = an.count_tasks_per_level_pairs(rows, cols, g)
        leaf = per[max(per)]
        total = sum(per.values())
        print(f"overlap{dim}d,leaf,{leaf},")
        print(f"overlap{dim}d,total,{total},")
        # locality: total within small factor of leaf count (paper §5.1)
        assert total < 3.0 * leaf

    # Fig 4 right: R-MAT locality sweep
    for a in (0.25, 0.4, 0.6, 0.8, 0.95):
        rows, cols = rmat_pairs(10, 5.0, a, seed=2)
        per = an.count_tasks_per_level_pairs(rows, cols, 1 << 10)
        leaf = per[max(per)]
        total = sum(per.values())
        print(f"rmat_a{a},leaf,{leaf},")
        print(f"rmat_a{a},total,{total},")


if __name__ == "__main__":
    main()
