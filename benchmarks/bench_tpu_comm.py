"""Paper Fig 14 analogue on the TPU engine: compiled-HLO collective bytes.

Weak scaling (N proportional to p) of the banded distributed multiply:
Morton-locality halo exchange (core/distributed.py) vs SpSUMMA
all_gather (core/spsumma.py).  Collective bytes per device are parsed
from the optimized SPMD module — the dry-run methodology end-to-end.

Runs itself in subprocesses (device count must be set before jax init).
CSV: scheme,p,N,coll_bytes_per_dev,halo_hops_or_pgrid.
"""
import os
import subprocess
import sys

_CHILD = "_child"


def child(scheme: str, p: int, n: int) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import distributed as dist, spsumma
    from repro.core.patterns import banded_mask, values_for_mask, \
        block_mask_from_element_mask
    from repro.launch import roofline

    bs = 8
    a = values_for_mask(banded_mask(n, 12), seed=1).astype(np.float32)
    ma = block_mask_from_element_mask(np.abs(a) > 0, bs)
    if scheme == "halo":
        plan = dist.plan_distribution(ma, ma, bs, p)
        ab, ar, ac = dist.distribute_morton(a, bs, plan)
        mesh = jax.make_mesh((p,), ("dev",))
        fn = dist.make_halo_spmm(mesh, "dev", plan)
        args = [jnp.asarray(x) for x in (ab, ar, ac, ab, ar, ac)]
        compiled = fn.lower(*args).compile()
        extra = plan.halo_hops
    elif scheme == "demand":
        dplan = dist.plan_demand(ma, ma, bs, p)
        base = dist.plan_distribution(ma, ma, bs, p)
        ab, ar, ac = dist.distribute_morton(a, bs, base)
        mesh = jax.make_mesh((p,), ("dev",))
        fn = dist.make_demand_spmm(mesh, "dev", dplan)
        args = [jnp.asarray(x) for x in (ab, ar, ac, ab, ar, ac)]
        compiled = fn.lower(*args).compile()
        extra = len(dplan.shifts)
    else:
        pg = int(np.sqrt(p))
        sp = spsumma.plan_summa(ma, ma, bs, pg)
        ab, ar, ac = spsumma.distribute_panels(a, bs, sp)
        mesh = jax.make_mesh((pg, pg), ("pr", "pc"))

        def run(*xs):
            return spsumma.summa_spmm(mesh, ("pr", "pc"), sp, *xs)

        args = [jnp.asarray(x) for x in (ab, ar, ac, ab, ar, ac)]
        compiled = jax.jit(run).lower(*args).compile()
        extra = pg
    coll = roofline.collective_bytes(compiled.as_text())
    print(f"{scheme},{p},{n},{coll},{extra}")


def main() -> None:
    print("scheme,p,N,coll_bytes_per_dev,halo_hops_or_pgrid")
    sys.stdout.flush()
    for p in (4, 16, 64):
        n = 256 * p
        for scheme in ("halo", "demand", "summa"):
            env = dict(os.environ)
            env["XLA_FLAGS"] = \
                f"--xla_force_host_platform_device_count={p}"
            res = subprocess.run(
                [sys.executable, __file__, _CHILD, scheme, str(p),
                 str(n)], capture_output=True, text=True, env=env,
                timeout=1800)
            if res.returncode:
                print(f"{scheme},{p},{n},FAILED,{res.stderr[-200:]}")
            else:
                print(res.stdout.strip().splitlines()[-1])
            sys.stdout.flush()


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == _CHILD:
        child(sys.argv[2], int(sys.argv[3]), int(sys.argv[4]))
    else:
        main()
