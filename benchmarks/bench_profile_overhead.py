"""Tracing overhead guard: observability must be (nearly) free.

Two contracts from DESIGN.md §8, asserted here and tracked as a CI
artifact:

1. **Traced runs stay cheap** — ``Session(trace=True)`` on the
   bench_expr_reuse overhead workload (banded eager multiply, wall time
   dominated by task registration) adds < 3% over the untraced default
   (min-of-N timings, alternating order, same twin estimators as
   bench_expr_reuse).
2. **The no-op path is free and inert** — the default ``NOOP`` tracer's
   span context manager costs nanoseconds per call (measured directly),
   which over the span count of the traced run amounts to ~0% of the
   untraced wall time; and tracing changes the task program not at all
   (``task_counts()`` identical with tracing on and off).

Writes ``BENCH_profile_overhead.json`` (``--out``) plus a
Perfetto-loadable ``profile_overhead.trace.json`` from the traced run.
``--quick`` shrinks sizes for CI.
"""
import argparse
import json
import pathlib
import time

try:
    from benchmarks._artifact import write_artifact
except ImportError:                     # run directly from benchmarks/
    from _artifact import write_artifact


def bench_traced(n: int, d: int, leaf_n: int, bs: int, repeats: int
                 ) -> dict:
    """Traced vs untraced eager multiply, min-of-N + median-pair."""
    from repro import Session
    from repro.core.patterns import banded_mask, values_for_mask

    a = values_for_mask(banded_mask(n, d), seed=1)

    def run(trace):
        sess = Session(leaf_n=leaf_n, bs=bs, trace=trace)
        A = sess.from_dense(a)
        _ = A @ A
        return sess

    # identity: the no-op/traced paths register the same task program
    off, on = run(False), run(True)
    assert off.task_counts() == on.task_counts(), \
        "tracing changed the task graph"
    n_spans = len(on.tracer.spans)

    times = {"off": [], "on": []}
    pair = (("off", False), ("on", True))
    for r in range(repeats):
        # alternate order per repeat so drift hits both sides equally
        for name, tr in (pair if r % 2 == 0 else pair[::-1]):
            t0 = time.perf_counter()
            run(tr)
            times[name].append(time.perf_counter() - t0)
    t_off, t_on = min(times["off"]), min(times["on"])
    # twin estimators (see bench_expr_reuse.bench_overhead): ratio of
    # min-of-N floors, and median of back-to-back pair ratios; a real
    # overhead shifts both, a one-sided noise burst only one
    ratios = sorted(o / f for o, f in zip(times["on"], times["off"]))
    med_pair = ratios[len(ratios) // 2]
    return {
        "n": n, "d": d, "leaf_n": leaf_n, "bs": bs, "repeats": repeats,
        "n_spans": n_spans,
        "off_s": t_off, "on_s": t_on,
        "overhead_min": t_on / t_off - 1.0,
        "overhead_median_pair": med_pair - 1.0,
        "overhead": min(t_on / t_off, med_pair) - 1.0,
        "off_s_all": times["off"], "on_s_all": times["on"],
    }


def bench_noop_span(iters: int) -> dict:
    """Per-call cost of the span context manager, no-op vs live."""
    from repro.obs import NOOP, Tracer

    def loop(tracer):
        span = tracer.span
        t0 = time.perf_counter()
        for _ in range(iters):
            with span("x"):
                pass
        return time.perf_counter() - t0

    t0 = time.perf_counter()
    for _ in range(iters):
        pass
    t_empty = time.perf_counter() - t0
    t_noop = min(loop(NOOP) for _ in range(5))
    live = Tracer()
    t_live = loop(live)
    live.clear()
    return {
        "iters": iters,
        "empty_loop_ns": t_empty / iters * 1e9,
        "noop_span_ns": t_noop / iters * 1e9,
        "live_span_ns": t_live / iters * 1e9,
    }


def write_trace(n: int, d: int, leaf_n: int, bs: int,
                path: pathlib.Path) -> int:
    """One traced run (build + multiply + simulate) -> Perfetto JSON."""
    from repro import Session
    from repro.core.patterns import banded_mask, values_for_mask
    from repro.obs import span_events, write_chrome_trace

    a = values_for_mask(banded_mask(n, d), seed=1)
    sess = Session(leaf_n=leaf_n, bs=bs, trace=True)
    A = sess.from_dense(a)
    _ = A @ A
    sess.simulate(p=4)
    write_chrome_trace(path, span_events(sess.tracer))
    return len(sess.tracer.spans)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized: smaller matrix, fewer repeats")
    ap.add_argument("--out", type=pathlib.Path,
                    default=pathlib.Path("BENCH_profile_overhead.json"))
    ap.add_argument("--trace-out", type=pathlib.Path,
                    default=pathlib.Path("profile_overhead.trace.json"))
    args = ap.parse_args()

    d, leaf_n, bs = 48, 64, 8
    if args.quick:
        n, repeats, iters = 512, 15, 50_000
    else:
        n, repeats, iters = 1024, 25, 200_000

    traced = bench_traced(n, d, leaf_n, bs, repeats)
    noop = bench_noop_span(iters)
    trace_spans = write_trace(n, d, leaf_n, bs, args.trace_out)
    # the no-op contribution over this workload's span count, as a
    # fraction of the untraced wall time — the "~0%" claim, quantified
    noop_frac = (noop["noop_span_ns"] * 1e-9 * traced["n_spans"]
                 / traced["off_s"])

    rec = {"traced": traced, "noop": noop,
           "noop_workload_fraction": noop_frac,
           "trace_json_spans": trace_spans}
    printable = dict(rec, traced={k: v for k, v in traced.items()
                                  if not k.endswith("_all")})
    print(json.dumps(printable, indent=1, sort_keys=True))
    write_artifact(args.out, "profile_overhead", rec,
                   params={"quick": args.quick, "n": n, "d": d,
                           "leaf_n": leaf_n, "bs": bs,
                           "repeats": repeats, "noop_iters": iters})
    print(f"wrote {args.out} and {args.trace_out}")

    ov = traced["overhead"]
    assert ov < 0.03, \
        f"Session(trace=True) adds {ov * 100:.1f}% over the untraced " \
        f"run (budget: 3%)"
    assert noop_frac < 1e-3, \
        f"no-op tracer costs {noop_frac * 100:.3f}% of the workload " \
        f"(budget: 0.1%)"
    print(f"traced overhead {ov * 100:+.2f}% "
          f"(noop span {noop['noop_span_ns']:.0f} ns/call, "
          f"{noop_frac * 100:.4f}% of workload)")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
