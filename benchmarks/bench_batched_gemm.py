"""Paper Table 2: batched small-GEMM peak throughput vs block size.

The paper measures cuBLAS batched gemm on K20 GPUs.  Our target is the
TPU MXU; on this CPU-only box we report (a) measured XLA-fallback
throughput (relative trend) and (b) the roofline-PROJECTED TPU v5e
throughput per block size: util = min(1, AI / (peak/bw)) where
AI = bs/3 flops/byte (bf16) for a streamed batch, against the v5e ridge
of 197e12/819e9 = 241 flops/byte.  This reproduces the paper's
observation that small blocks starve the compute unit — on the MXU the
starvation is worse, which is why the leaf block is retuned to 128+
(DESIGN.md §3).  CSV: bs,batch,cpu_gflops,ai_flops_per_byte,
projected_v5e_gflops,pct_peak.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref

PEAK = 197e12
BW = 819e9


def main() -> None:
    print("bs,batch,cpu_gflops,ai_flops_per_byte,projected_v5e_gflops,"
          "pct_peak")
    rng = np.random.default_rng(0)
    for bs in (16, 32, 48, 64, 96, 128):
        batch = max(1, (1 << 22) // (bs * bs))     # ~4M elements per op
        a = jnp.asarray(rng.standard_normal((batch, bs, bs)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((batch, bs, bs)), jnp.float32)
        f = jax.jit(ref.batched_gemm_ref)
        f(a, b).block_until_ready()
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            f(a, b).block_until_ready()
        dt = (time.perf_counter() - t0) / reps
        flops = 2.0 * batch * bs ** 3
        cpu_gflops = flops / dt / 1e9
        # streamed batch (unique A, B, C per multiply), bf16:
        # bytes = 3 * bs^2 * 2 per op -> AI = 2 bs^3 / 6 bs^2 = bs / 3
        ai = bs / 3.0
        ridge = PEAK / BW
        proj = PEAK * min(1.0, ai / ridge)
        print(f"{bs},{batch},{cpu_gflops:.1f},{ai:.1f},"
              f"{proj/1e9:.0f},{100 * proj / PEAK:.1f}")


if __name__ == "__main__":
    main()
