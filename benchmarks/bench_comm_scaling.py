"""Paper Table 1 + Figs 12-13: weak-scaling communication per process.

ClusterSim (faithful Chunks-and-Tasks semantics: work stealing, chunk
cache, owner-embedded ids) on banded matrices with N proportional to p,
for regular multiply and symmetric square, against the SpSUMMA prediction
of eq (17).  CSV: op,p,N,avg_MB_per_proc,max_MB_per_proc,spsumma_MB,active.
"""
import numpy as np

from repro.core import analysis as an
from repro.core.patterns import banded_mask, values_for_mask
from repro.core.quadtree import QTParams, qt_from_dense
from repro.core.multiply import qt_multiply, qt_sym_square
from repro.core.tasks import ClusterSim, CTGraph


def run(op: str, p: int, n_per_proc: int, d: int, leaf_n: int, bs: int):
    n = n_per_proc * p
    params = QTParams(n, leaf_n, bs)
    a = values_for_mask(banded_mask(n, d), seed=1, symmetric=True)
    g = CTGraph()
    sim = ClusterSim(p, seed=0)
    if op == "multiply":
        ra = qt_from_dense(g, a, params)
        rb = qt_from_dense(g, a, params)
        sim.run(g)          # build phase: placement follows construction
        sim.reset_stats()
        qt_multiply(g, params, ra, rb)
    else:
        rs = qt_from_dense(g, a, params, upper=True)
        sim.run(g)
        sim.reset_stats()
        qt_sym_square(g, params, rs)
    res = sim.run(g)
    per = np.asarray(res.bytes_received, np.float64)
    # elements fetched per process under random-permute SpSUMMA, eq (17)
    m = 2 * d + 1
    sp_bytes = an.spsumma_weak_scaling_elements(m, n_per_proc, p) * 8
    active = float(np.mean(res.active_fraction))
    return per.mean() / 1e6, per.max() / 1e6, sp_bytes / 1e6, active, n


def main() -> None:
    print("op,p,N,avg_MB_per_proc,max_MB_per_proc,spsumma_MB,active")
    n_per, d = 256, 24
    for op in ("multiply", "sym_square"):
        rows = []
        for p in (2, 4, 8, 16):
            avg, mx, sp, act, n = run(op, p, n_per, d, leaf_n=64, bs=8)
            rows.append(avg)
            print(f"{op},{p},{n},{avg:.3f},{mx:.3f},{sp:.3f},{act:.2f}")
        # Table 1: quadtree-banded comm/process flattens as p grows
        # (asymptotic O(1)); SpSUMMA keeps growing as sqrt(p).  Assert the
        # LATE-stage growth ratio beats sqrt(2) clearly.
        late = rows[-1] / rows[-2]
        assert late < 1.35, f"{op}: late comm growth {late:.2f}x"


if __name__ == "__main__":
    main()
