"""Paper Table 1 + Figs 12-13: weak-scaling communication per worker.

Drives the Chunks-and-Tasks runtime simulator through the Session/Matrix
facade (repro.api over repro.runtime.scheduler: work stealing, chunk
cache, owner-embedded ids) over the paper's pattern families with matched
work per worker (N proportional to p), under both
the locality-aware ``parent-worker`` chunk placement (the paper's model:
placement follows the work-stealing execution) and the locality-oblivious
``random`` baseline:

* ``banded``   — regular multiply, bandwidth 2d+1 (Figs 12-13);
* ``random``   — uniform sparsity at fixed nnz/row (no data locality:
                 comm per worker is *not* expected to stay flat);
* ``overlap``  — 3-D particle S^2 symmetric square (Figs 10-11 matrices).

The Table 1 contrast: for local patterns under parent-worker placement,
max per-worker bytes received stays essentially constant as p grows, while
the random-placement baseline pays a locality gap that exceeds the
sqrt(p/4) SpSUMMA growth rate of eq (17), whose closed-form curve is
emitted alongside for reference.

CSV on stdout; ``--out FILE`` additionally writes the full JSON record
(the perf-trajectory artifact); ``--quick`` runs a reduced banded-only
sweep sized for CI.
"""
import argparse
import pathlib

import numpy as np

from repro import Session
from repro.core import analysis as an
from repro.core.patterns import (banded_mask, divide_space_order,
                                 overlap_pairs, particle_cloud, random_mask,
                                 values_for_mask)

try:
    from benchmarks._artifact import write_artifact
except ImportError:                     # run directly from benchmarks/
    from _artifact import write_artifact


def _measure(sess, p, op):
    """Build phase then measured phase on the session's cluster."""
    sess.simulate(p=p)       # placements follow the build task program
    op()
    return sess.simulate(fresh_stats=True)


def run_banded(p, placement, n_per=256, d=24, leaf_n=64, bs=8, seed=0):
    n = n_per * p
    a = values_for_mask(banded_mask(n, d), seed=1, symmetric=True)
    sess = Session(leaf_n=leaf_n, bs=bs, placement=placement, seed=seed)
    A = sess.from_dense(a)
    B = sess.from_dense(a)
    rep = _measure(sess, p, lambda: A @ B)
    sp_bytes = an.spsumma_weak_scaling_elements(2 * d + 1, n_per, p) * 8
    return rep, n, sp_bytes


def run_random(p, placement, n_per=64, m=6, leaf_n=16, bs=4, seed=0):
    n = n_per * p
    a = values_for_mask(random_mask(n, m / n, seed=2), seed=1)
    sess = Session(leaf_n=leaf_n, bs=bs, placement=placement, seed=seed)
    A = sess.from_dense(a)
    B = sess.from_dense(a)
    rep = _measure(sess, p, lambda: A @ B)
    sp_bytes = an.spsumma_weak_scaling_elements(m, n_per, p) * 8
    return rep, n, sp_bytes


# ~256 basis functions per worker: npart = n_per_dim^3 grows with p
_OVERLAP_DIMS = {2: 8, 4: 10, 8: 13, 16: 16}


def run_overlap(p, placement, radius=4.0, seed=0):
    coords = particle_cloud(_OVERLAP_DIMS[p], 3, seed=3)
    order = divide_space_order(coords)
    rows, cols = overlap_pairs(coords, radius, order=order)
    npart = len(coords)
    n = 1 << int(np.ceil(np.log2(npart)))
    sess = Session(leaf_n=max(n // 16, 32), bs=8, placement=placement,
                   seed=seed)
    S = sess.from_pattern(rows, cols, n, upper=True)
    rep = _measure(sess, p, S.sym_square)
    # SpSUMMA reference with m = avg nnz/row of S, weak scaling in npart
    m = len(rows) / npart
    sp_bytes = an.spsumma_weak_scaling_elements(m, npart / p, p) * 8
    return rep, n, sp_bytes


RUNNERS = {"banded": run_banded, "random": run_random,
           "overlap": run_overlap}

# work per worker is matched within a pattern, but total work for the
# random pattern still grows superlinearly (eq (7): (delta N^2)^{3/2}) —
# cap the locality-free patterns so the sweep stays minutes, not hours
MAX_P = {"banded": 16, "random": 8, "overlap": 8}


def sweep(patterns, placements, ps, quick=False):
    records = []
    print("pattern,placement,p,N,avg_MB_per_proc,max_MB_per_proc,"
          "pushed_MB_avg,spsumma_MB,active,parallel_eff,steals,"
          "critical_path_ms")
    for pattern in patterns:
        for placement in placements:
            for p in ps:
                if p > MAX_P[pattern]:
                    continue
                kwargs = {}
                if quick and pattern == "banded":
                    kwargs = dict(n_per=128, leaf_n=32)
                rep, n, sp_bytes = RUNNERS[pattern](p, placement, **kwargs)
                summ = an.comm_summary(rep.bytes_received)
                cp = an.critical_path_summary(
                    rep.crit.work_s, rep.crit.length_s, p, rep.makespan)
                rec = {
                    "pattern": pattern, "placement": placement,
                    "p": p, "n": n,
                    "avg_MB": summ["avg_bytes"] / 1e6,
                    "max_MB": summ["max_bytes"] / 1e6,
                    "imbalance": summ["imbalance"],
                    "pushed_MB_avg": float(np.mean(rep.bytes_pushed)) / 1e6,
                    "spsumma_MB": sp_bytes / 1e6,
                    "active": float(np.mean(rep.active_fraction)),
                    "steals": rep.steals,
                    **{k: cp[k] for k in ("makespan_s", "work_s",
                                          "critical_path_s",
                                          "parallel_efficiency")},
                }
                records.append(rec)
                print(f"{pattern},{placement},{p},{n},"
                      f"{rec['avg_MB']:.3f},{rec['max_MB']:.3f},"
                      f"{rec['pushed_MB_avg']:.3f},{rec['spsumma_MB']:.3f},"
                      f"{rec['active']:.2f},"
                      f"{rec['parallel_efficiency']:.2f},{rec['steals']},"
                      f"{rec['critical_path_s'] * 1e3:.2f}", flush=True)
    return records


def summarize(records):
    """Weak-scaling growth per (pattern, placement) + locality gaps."""
    out = {}
    by = {(r["pattern"], r["placement"], r["p"]): r for r in records}
    patterns = sorted({r["pattern"] for r in records})
    placements = sorted({r["placement"] for r in records})
    for pattern in patterns:
        entry = {}
        pat_ps = sorted({r["p"] for r in records if r["pattern"] == pattern})
        for placement in placements:
            series = {p: by[(pattern, placement, p)]["max_MB"]
                      for p in pat_ps if (pattern, placement, p) in by}
            if len(series) >= 2:
                # asymptotic growth measured from p=4 (p=2 has almost no
                # subtree boundaries and would flatter every policy)
                late = {p: v for p, v in series.items() if p >= 4}
                entry[placement] = {
                    "max_MB_by_p": series,
                    "growth": an.weak_scaling_growth(series),
                    "late_growth": an.weak_scaling_growth(late)
                    if len(late) >= 2 else None,
                }
        key_a, key_b = ("parent-worker", "random")
        if key_a in entry and key_b in entry:
            for metric, name in (("max_MB", "locality_gap"),
                                 ("avg_MB", "locality_gap_avg")):
                entry[name] = {
                    p: by[(pattern, key_b, p)][metric]
                    / by[(pattern, key_a, p)][metric]
                    for p in pat_ps
                    if (pattern, key_a, p) in by and (pattern, key_b, p) in by}
        # eq (17): SpSUMMA's per-process fetch rate grows as sqrt(p);
        # sqrt(p/4) is the growth the largest run would show had it scaled
        # at that rate from the p=4 reference point
        entry["spsumma_rate_from_p4"] = float(np.sqrt(max(pat_ps) / 4.0))
        out[pattern] = entry
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced banded-only sweep (CI / perf trajectory)")
    ap.add_argument("--out", type=pathlib.Path, default=None,
                    help="write full JSON record to this path")
    ap.add_argument("--patterns", nargs="+", default=None,
                    choices=sorted(RUNNERS))
    ap.add_argument("--placements", nargs="+",
                    default=["parent-worker", "random"],
                    choices=["parent-worker", "round-robin", "random"])
    args = ap.parse_args()

    if args.quick:
        patterns = args.patterns or ["banded"]
        ps = (4, 16)
    else:
        patterns = args.patterns or ["banded", "random", "overlap"]
        ps = (2, 4, 8, 16)

    records = sweep(patterns, args.placements, ps, quick=args.quick)
    summary = summarize(records)
    if args.out:
        write_artifact(args.out, "comm_scaling",
                       {"quick": args.quick, "ps": list(ps),
                        "records": records, "summary": summary},
                       params={"quick": args.quick, "ps": list(ps),
                               "patterns": patterns,
                               "placements": args.placements})
        print(f"wrote {args.out}")

    # Table 1 regression (banded pattern): locality-aware placement keeps
    # max bytes/worker essentially flat in weak scaling (p=4 -> p_max within
    # 2x), while the locality-oblivious baseline sits a growing gap above
    # it that reaches the sqrt(p/4) SpSUMMA rate of eq (17).
    if "banded" in summary:
        s = summary["banded"]
        rate = s["spsumma_rate_from_p4"]
        if s.get("parent-worker", {}).get("late_growth") is not None:
            g = s["parent-worker"]["late_growth"]
            assert g < 2.0, f"banded parent-worker comm grew {g:.2f}x"
        if "locality_gap_avg" in s and s["locality_gap_avg"]:
            p_hi = max(s["locality_gap_avg"])
            gap = s["locality_gap_avg"][p_hi]
            assert gap >= rate, \
                f"avg locality gap {gap:.2f}x < SpSUMMA rate {rate:.2f}x"
            gap_max = s["locality_gap"][p_hi]
            assert gap_max >= 0.9 * rate, \
                f"max locality gap {gap_max:.2f}x << rate {rate:.2f}x"


if __name__ == "__main__":
    main()
