"""Serving throughput vs coalesced batch size, with tail latency.

    PYTHONPATH=src python benchmarks/bench_serve.py [--quick] \
        [--out BENCH_serve.json]

Measures the plan-serving subsystem (DESIGN.md §9) on a repeated-shape
multiply workload:

* **Cache-hit rate** — after one warmup pass per distinct request shape,
  every request must rebind-replay an existing replica: the bench
  asserts a >= 90% shared-cache hit rate on the measured workload and
  **zero new task registrations** after warmup.
* **Throughput vs batch size** — the same request stream served with
  ``max_inflight`` in {1, 2, 4, 8}: coalescing more plans per fused
  kernel dispatch amortizes per-dispatch overhead, so requests/s at the
  best coalesced batch size must beat ``max_inflight=1``.  (The curve
  peaks and flattens once same-shape requests outnumber replicas.)
* **Tail latency** — p50/p95/p99 of per-request submit-to-done latency
  per batch-size point.
* **Correctness** — every served result is pinned (bitwise, float32
  readback tolerance) to the same request served alone, so coalescing
  is an execution detail, not a numerics change.

The artifact (``BENCH_serve.json``) carries one row per batch size:
``{max_inflight, requests, requests_per_s, p50_ms, p95_ms, p99_ms,
hit_rate, merged_waves, solo_waves}``.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
from _artifact import write_artifact  # noqa: E402

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
from repro.serve import PlanServer, Request  # noqa: E402


def percentile_ms(lat_s: list, q: float) -> float:
    return float(np.percentile(np.asarray(lat_s), q) * 1e3)


def make_operands(n: int, n_mats: int, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    return {f"M{i}": rng.standard_normal((n, n)) for i in range(n_mats)}


def request_stream(names: list, count: int) -> list:
    """A repeated-shape workload: products cycling over registered pairs."""
    reqs = []
    for i in range(count):
        a = names[i % len(names)]
        b = names[(i + 1) % len(names)]
        reqs.append(Request.multiply(a, b))
    return reqs


def serve_point(mats: dict, reqs: list, max_inflight: int, *, n_sessions: int,
                leaf_n: int, bs: int, reps: int = 1) -> tuple:
    """Serve the stream at one batch size; returns (row, results).

    The measured pass runs ``reps`` times against the warm server and the
    fastest pass is reported — single-pass wall times on a shared CPU are
    too noisy to pin a ~20% dispatch-amortization effect.
    """
    srv = PlanServer(engine="pallas", n_sessions=n_sessions,
                     max_inflight=max_inflight,
                     max_queue=max(len(reqs), 4), leaf_n=leaf_n, bs=bs)
    for name, a in mats.items():
        srv.register(name, a)

    # warmup: serve the stream once — this compiles every replica the
    # measured pass will touch (including the extra per-session replicas
    # concurrent same-shape requests need) and pays the one-time jit of
    # the fused kernels
    for r in reqs:
        srv.submit(r)
    srv.drain()
    tasks_after_warmup = srv.task_count()
    hits0 = srv.cache.counters()["hits"]
    misses0 = srv.cache.counters()["misses"]

    wall, tickets = None, None
    for _ in range(reps):
        t0 = time.perf_counter()
        cand = [srv.submit(r) for r in reqs]
        srv.drain()
        w = time.perf_counter() - t0
        assert all(t.done for t in cand), \
            [t.error for t in cand if not t.done]
        if wall is None or w < wall:
            wall, tickets = w, cand
    assert srv.task_count() == tasks_after_warmup, (
        f"warm serving registered tasks: {tasks_after_warmup} -> "
        f"{srv.task_count()}")
    c = srv.cache.counters()
    hits = c["hits"] - hits0
    misses = c["misses"] - misses0
    hit_rate = hits / max(hits + misses, 1)
    lat = [t.latency_s for t in tickets]
    return {
        "max_inflight": max_inflight,
        "requests": len(reqs),
        "wall_s": wall,
        "requests_per_s": len(reqs) / wall,
        "p50_ms": percentile_ms(lat, 50),
        "p95_ms": percentile_ms(lat, 95),
        "p99_ms": percentile_ms(lat, 99),
        "hit_rate": hit_rate,
        "merged_waves": srv.coalescer.merged_waves,
        "solo_waves": srv.coalescer.solo_waves,
        "tasks": tasks_after_warmup,
    }, [t.result for t in tickets]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller matrices and request count (CI)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()

    # serving-typical regime: many small repeated-shape products, where
    # per-dispatch overhead is a real cost to amortize.  (At much larger
    # waves the interpret-mode kernel dominates and grows superlinearly
    # with packed size, so coalescing is neutral there — the win this
    # bench pins is dispatch amortization, not kernel speedup.)  The full
    # run spreads replicas over 4 sessions so concurrent same-shape
    # requests coalesce cleanly instead of entangling on shared
    # same-session templates.
    n = 32
    leaf_n, bs = 16, 4
    n_mats = 3
    count = 8 if args.quick else 32
    batch_sizes = [1, 2, 4] if args.quick else [1, 2, 4, 8]
    n_sessions = 2 if args.quick else 4
    reps = 2 if args.quick else 3

    mats = make_operands(n, n_mats)
    names = sorted(mats)
    reqs = request_stream(names, count)

    # serial reference: every request served alone (max_inflight=1 in a
    # fresh server) — the numerical pin for every coalesced point
    print(f"bench_serve: n={n} requests={count} shapes={n_mats} "
          f"batch sizes={batch_sizes}")
    ref_row, ref_results = serve_point(
        mats, reqs, 1, n_sessions=1, leaf_n=leaf_n, bs=bs, reps=reps)

    rows = []
    for mi in batch_sizes:
        row, results = serve_point(mats, reqs, mi, n_sessions=n_sessions,
                                   leaf_n=leaf_n, bs=bs, reps=reps)
        for got, want in zip(results, ref_results):
            np.testing.assert_array_equal(
                got, want,
                err_msg=f"coalesced serving (max_inflight={mi}) diverged "
                        f"from serial execution")
        assert row["hit_rate"] >= 0.90, (
            f"cache-hit rate {row['hit_rate']:.2f} < 0.90 at "
            f"max_inflight={mi}")
        rows.append(row)
        print(f"  max_inflight={mi}: {row['requests_per_s']:.2f} req/s  "
              f"p50={row['p50_ms']:.1f}ms p95={row['p95_ms']:.1f}ms "
              f"p99={row['p99_ms']:.1f}ms hit_rate={row['hit_rate']:.2f} "
              f"merged_waves={row['merged_waves']}")

    # coalescing must buy throughput over serial serving at its sweet
    # spot; past it, replica stalls (same-shape requests outnumbering
    # replicas) and same-session template entanglement flatten the curve,
    # so the claim is about the best coalesced point, not the largest
    thr = {r["max_inflight"]: r["requests_per_s"] for r in rows}
    best = max((mi for mi in thr if mi > 1), key=lambda mi: thr[mi],
               default=None)
    assert best is not None and thr[best] > thr[1], (
        f"coalesced serving never beat serial: {thr[1]:.2f} req/s at "
        f"max_inflight=1 vs {thr}")

    doc_params = {"quick": args.quick, "n": n, "leaf_n": leaf_n, "bs": bs,
                  "n_mats": n_mats, "requests": count,
                  "n_sessions": n_sessions, "reps": reps}
    path = write_artifact(args.out, "serve",
                          {"rows": rows, "serial_reference": ref_row},
                          params=doc_params)
    print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
