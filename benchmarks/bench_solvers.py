"""Electronic-structure solver sweep: factorization methods + tau chains.

Two sweeps over the solver suite (DESIGN.md §11):

1. **Inverse factorization** — for each SPD decay family (banded / s2 /
   random) run every ``inverse_factor`` method and record iterations,
   measured residual, leaf flops, multiply tasks ("touched subtrees")
   and the task-graph communication demand.  The acceptance contract:
   every method's Z reproduces the dense reference residual, and the
   localized method touches fewer subtrees than the global refinement
   on every decay family.

2. **Accuracy-scaled multiply chains** — sweep the ``TauPolicy`` target
   over a fixed factor chain and record the per-step taus, the rigorous
   accumulated bound, measured error, flops and pruned flops.  Contract:
   measured error <= accumulated bound <= target (when nonzero), and
   flops are monotone non-increasing as the target loosens.

Emits ``BENCH_solvers.json`` (rendered by ``launch/report.py``);
``--quick`` runs the CI-sized sweep.
"""
import argparse
import math
import pathlib

import numpy as np

from repro import Session
from repro.core import analysis as an
from repro.core.patterns import (banded_mask, divide_space_order,
                                 overlap_mask, particle_cloud, random_mask,
                                 values_for_mask)
from repro.solvers import TauPolicy, inverse_factor, multiply_chain

try:
    from benchmarks._artifact import write_artifact
except ImportError:                     # run directly from benchmarks/
    from _artifact import write_artifact

METHODS = ("recursive", "localized", "global")
TARGETS = (0.0, 1e-7, 1e-5, 1e-3, 1e-1)      # exact -> loosest
TARGETS_QUICK = (0.0, 1e-5, 1e-1)


def make_spd(pattern: str, n: int, seed: int = 0) -> np.ndarray:
    """Diagonally dominant SPD matrix with the named sparsity/decay."""
    rng = np.random.default_rng(seed)
    if pattern == "banded":
        dist = np.abs(np.subtract.outer(np.arange(n), np.arange(n)))
        a = values_for_mask(banded_mask(n, 8), seed=seed) * 0.5 ** dist
    elif pattern == "s2":
        n_per_dim = round(n ** (1.0 / 3.0))
        while n_per_dim ** 3 > n:
            n_per_dim -= 1
        coords = particle_cloud(n_per_dim, 3, seed=seed)
        order = divide_space_order(coords)
        mask = overlap_mask(coords, 14.0, order=order)
        pts = coords[order]
        dist = np.sqrt(((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1))
        a = np.zeros((n, n))
        m = len(coords)
        a[:m, :m] = values_for_mask(mask, seed=seed + 1) * np.exp(-0.7 * dist)
    else:                                              # random decay
        a = values_for_mask(random_mask(n, 0.15, seed=seed), seed=seed + 1)
        a *= 10.0 ** (-4.0 * rng.random((n, n)))
    a = (a + a.T) / 2.0
    off = np.abs(a).sum(axis=1) - np.abs(np.diag(a))
    a *= 0.45 / max(off.max(), 1e-12)
    np.fill_diagonal(a, 1.0)
    return a


def chain_factors(n: int, k: int, seed: int = 3) -> list:
    """Near-identity decayed factors (keeps chain norms O(1))."""
    rng = np.random.default_rng(seed)
    idx = np.arange(n)
    decay = np.exp(-0.6 * np.abs(idx[:, None] - idx[None, :]))
    return [np.eye(n) + 0.25 * decay * rng.standard_normal((n, n))
            for _ in range(k)]


def factor_point(pattern: str, method: str, s: np.ndarray, *, leaf_n: int,
                 bs: int, tol: float, tau: float) -> dict:
    sess = Session(leaf_n=leaf_n, bs=bs)
    S = sess.from_dense(s, upper=True)
    n_before = len(sess.graph.nodes)
    kw = dict(tol=tol, tau=tau) if method != "recursive" else {}
    z, rep = inverse_factor(S, method=method, **kw)
    zd = z.to_dense()
    n = s.shape[0]
    measured = float(np.linalg.norm(zd.T @ s @ zd - np.eye(n)))
    return {
        "pattern": pattern, "method": method, "n": n,
        "iterations": rep.iterations, "splits": rep.splits,
        "residual": rep.residual, "measured_residual": measured,
        "converged": rep.converged, "flops": rep.flops,
        "multiply_tasks": rep.multiply_tasks,
        "comm_demand_bytes": an.task_comm_demand(sess.graph, n_before),
    }


def chain_point(target: float, mats: list, exact: np.ndarray, *,
                leaf_n: int, bs: int) -> dict:
    sess = Session(leaf_n=leaf_n, bs=bs)
    ms = [sess.from_dense(m) for m in mats]
    n_before = len(sess.graph.nodes)
    policy = TauPolicy(target=target) if target > 0.0 else None
    p, rep = multiply_chain(ms, policy=policy)
    err = float(np.linalg.norm(p.to_dense() - exact))
    return {
        "target": target, "steps": rep.steps, "taus": rep.taus,
        "accumulated_bound": rep.accumulated_bound,
        "measured_error": err, "flops": rep.flops,
        "pruned_flops": rep.pruned_flops,
        "comm_demand_bytes": an.task_comm_demand(sess.graph, n_before),
    }


def check_factors(rows: list) -> None:
    for r in rows:
        # the reported residual is itself a measurement; it must agree
        # with the dense readback up to leaf float accumulation
        assert r["measured_residual"] <= r["residual"] + 1e-9, (
            f"{r['pattern']}/{r['method']}: dense residual "
            f"{r['measured_residual']} exceeds reported {r['residual']}")
        assert r["converged"], f"{r['pattern']}/{r['method']} diverged"
    by = {(r["pattern"], r["method"]): r for r in rows}
    for pattern in {r["pattern"] for r in rows}:
        loc, glo = by[(pattern, "localized")], by[(pattern, "global")]
        assert loc["multiply_tasks"] < glo["multiply_tasks"], (
            f"{pattern}: localized touched {loc['multiply_tasks']} "
            f"subtrees, global only {glo['multiply_tasks']}")


def check_chain(rows: list, mats: list) -> None:
    slack = 1e-9 * math.prod(float(np.linalg.norm(m)) for m in mats)
    for r in rows:
        assert r["measured_error"] <= r["accumulated_bound"] + slack, (
            f"target={r['target']}: error {r['measured_error']} > "
            f"bound {r['accumulated_bound']}")
        if r["target"] > 0.0:
            assert r["accumulated_bound"] <= r["target"], (
                f"target={r['target']}: accumulated bound "
                f"{r['accumulated_bound']} overran the target")
    # rows are swept from exact to loosest: pruning only grows
    flops = [r["flops"] for r in rows]
    assert an.is_monotone_nonincreasing(flops), \
        f"chain flops not monotone in target: {flops}"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweep (CI / perf trajectory)")
    ap.add_argument("--out", type=pathlib.Path, default=None,
                    help="write JSON record to this path")
    ap.add_argument("--patterns", nargs="+",
                    default=["banded", "s2", "random"],
                    choices=["banded", "s2", "random"])
    args = ap.parse_args()

    n, leaf_n, bs = (64, 16, 4) if args.quick else (128, 16, 4)
    tol, tau = 1e-4, 1e-7          # refinement exit / truncation threshold

    print("pattern,method,iters,residual,flops,multiply_tasks,comm_B")
    factor_rows = []
    for pattern in args.patterns:
        s = make_spd(pattern, n)
        for method in METHODS:
            r = factor_point(pattern, method, s, leaf_n=leaf_n, bs=bs,
                             tol=tol, tau=tau)
            factor_rows.append(r)
            print(f"{pattern},{method},{r['iterations']},"
                  f"{r['residual']:.3e},{r['flops']:.4g},"
                  f"{r['multiply_tasks']},{r['comm_demand_bytes']}",
                  flush=True)
    check_factors(factor_rows)

    targets = TARGETS_QUICK if args.quick else TARGETS
    mats = chain_factors(n, k=3 if args.quick else 4)
    exact = mats[0]
    for m in mats[1:]:
        exact = exact @ m
    print("target,steps,bound,error,flops,pruned_flops")
    chain_rows = []
    for target in targets:
        r = chain_point(target, mats, exact, leaf_n=leaf_n, bs=bs)
        chain_rows.append(r)
        print(f"{target:g},{r['steps']},{r['accumulated_bound']:.3e},"
              f"{r['measured_error']:.3e},{r['flops']:.4g},"
              f"{r['pruned_flops']:.4g}", flush=True)
    check_chain(chain_rows, mats)

    if args.out:
        write_artifact(
            args.out, "solvers",
            {"quick": args.quick, "factor_rows": factor_rows,
             "chain_rows": chain_rows,
             "asserts": {"residual_matches_dense": True,
                         "localized_lt_global_tasks": True,
                         "error_le_accumulated_bound": True,
                         "bound_le_target": True,
                         "chain_flops_monotone": True}},
            params={"quick": args.quick, "n": n, "leaf_n": leaf_n, "bs": bs,
                    "tol": tol, "tau": tau, "targets": list(targets),
                    "patterns": args.patterns})
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
