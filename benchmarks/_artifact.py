"""Shared benchmark-artifact writer: one envelope for every BENCH_*.json.

Every benchmark artifact at the repo root carries the same envelope::

    {"schema": 1, "bench": "<name>", "params": {...}, <payload keys>}

``schema`` versions the envelope itself, ``bench`` names the producing
script (its module name minus the ``bench_`` prefix), ``params`` records
the sweep configuration (quick mode, sizes, worker counts) so a stored
artifact is self-describing.  Payload keys stay at the top level, so
existing consumers (launch/report.py, the pinned-value tests, the CI
perf-trajectory checks) keep reading the same paths — the envelope is
additive.
"""
from __future__ import annotations

import json
import pathlib
from typing import Optional

SCHEMA_VERSION = 1


def artifact(bench: str, payload: dict,
             params: Optional[dict] = None) -> dict:
    """Assemble the enveloped artifact document (payload keys win)."""
    doc = {"schema": SCHEMA_VERSION, "bench": bench,
           "params": dict(params or {})}
    doc.update(payload)
    return doc


def write_artifact(path, bench: str, payload: dict,
                   params: Optional[dict] = None) -> pathlib.Path:
    """Write an enveloped ``BENCH_*.json`` artifact (stable formatting)."""
    path = pathlib.Path(path)
    doc = artifact(bench, payload, params)
    path.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")
    return path


def validate_artifact(doc: dict) -> dict:
    """Assert the envelope shape; returns the document unchanged."""
    if not isinstance(doc, dict) or doc.get("schema") != SCHEMA_VERSION:
        raise ValueError(f"not a bench artifact (schema={SCHEMA_VERSION})")
    if not isinstance(doc.get("bench"), str) or not doc["bench"]:
        raise ValueError("bench artifact missing 'bench' name")
    if not isinstance(doc.get("params"), dict):
        raise ValueError("bench artifact missing 'params' dict")
    return doc
