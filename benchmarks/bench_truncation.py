"""Error-controlled truncated multiply: tau sweep over decay patterns.

SpAMM-style hierarchical norm pruning (DESIGN.md §5) only pays off on
matrices whose elements decay away from a structural core — the paper's
electronic-structure workload (§6.2) and the follow-up truncated-multiply
papers (arXiv:1906.08148, arXiv:2011.11762).  This benchmark sweeps the
truncation threshold tau over three such families:

* ``banded``  — banded mask, magnitudes decaying exponentially with
                distance from the diagonal;
* ``s2``      — 3-D particle overlap pattern (divide-space ordered),
                magnitudes decaying exponentially with particle distance;
* ``random``  — uniform iid mask with log-uniform magnitude spread (no
                spatial locality: pruning is purely magnitude-driven).

For each (pattern, tau) a fresh Session builds A and B, runs the build
phase on the simulated cluster, registers ``A.multiply(B, tau=tau)`` and
replays the multiply phase — recording executed flops, task counts,
fetched bytes and critical path from the simulator, plus the measured
error ``||C_exact - C_tau||_F`` against the tau=0 result and the
worst-case bound reported by the TruncationReport.

Emits flops-vs-error and comm-vs-error curves as ``BENCH_truncation.json``
and asserts the acceptance contract: measured error never exceeds the
reported bound, and flops / tasks / fetched bytes are monotonically
non-increasing in tau (communication gets a small scheduler-noise
tolerance).  ``--quick`` runs a reduced sweep sized for CI.
"""
import argparse
import math
import pathlib

import numpy as np

from repro import Session
from repro.core import analysis as an
from repro.core.patterns import (banded_mask, divide_space_order,
                                 overlap_mask, particle_cloud, random_mask,
                                 values_for_mask)

try:
    from benchmarks._artifact import write_artifact
except ImportError:                     # run directly from benchmarks/
    from _artifact import write_artifact

TAUS = (0.0, 1e-8, 1e-6, 1e-4, 1e-3, 1e-2, 1e-1)
TAUS_QUICK = (0.0, 1e-6, 1e-3, 1e-1)


def banded_decay(n: int, d: int, alpha: float = 0.25, seed: int = 1
                 ) -> np.ndarray:
    """Banded matrix with exp(-alpha |i-j|) magnitude decay."""
    vals = values_for_mask(banded_mask(n, d), seed=seed)
    dist = np.abs(np.subtract.outer(np.arange(n), np.arange(n)))
    return vals * np.exp(-alpha * dist)


def s2_decay(n_per_dim: int, alpha: float = 0.9, radius: float = 12.0,
             seed: int = 3) -> np.ndarray:
    """3-D overlap pattern with exp(-alpha dist) magnitudes (S2-like)."""
    coords = particle_cloud(n_per_dim, 3, seed=seed)
    order = divide_space_order(coords)
    mask = overlap_mask(coords, radius, order=order)
    npart = len(coords)
    pts = coords[order]
    dist = np.sqrt(((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1))
    vals = values_for_mask(mask, seed=seed + 1) * np.exp(-alpha * dist)
    n = 1 << int(math.ceil(math.log2(npart)))
    out = np.zeros((n, n))
    out[:npart, :npart] = vals
    return out


def random_spread(n: int, delta: float, decades: float = 6.0, seed: int = 5
                  ) -> np.ndarray:
    """iid mask, magnitudes spread log-uniformly over ``decades``."""
    rng = np.random.default_rng(seed)
    vals = values_for_mask(random_mask(n, delta, seed=seed), seed=seed + 1)
    scale = 10.0 ** (-decades * rng.random((n, n)))
    return vals * scale


def make_inputs(pattern: str, quick: bool) -> tuple[np.ndarray, np.ndarray]:
    if pattern == "banded":
        # wide band + strong decay: far-off-diagonal blocks are present
        # structurally but numerically tiny, so whole subtrees prune
        n, d, alpha = (128, 48, 0.2) if quick else (256, 96, 0.1)
        return (banded_decay(n, d, alpha, seed=1),
                banded_decay(n, d, alpha, seed=2))
    if pattern == "s2":
        npd = 5 if quick else 6
        return s2_decay(npd, seed=3), s2_decay(npd, seed=7)
    if pattern == "random":
        n = 128 if quick else 256
        return random_spread(n, 0.08, seed=5), random_spread(n, 0.08, seed=9)
    raise ValueError(pattern)


SIM_SEEDS = (0, 1, 2)


def run_point(a: np.ndarray, b: np.ndarray, tau: float, *, leaf_n: int,
              bs: int, p: int) -> dict:
    """One (pattern, tau) measurement: build phase, truncated multiply,
    simulated multiply phase.

    Graph-side quantities (tasks, flops, error bound) are deterministic;
    the communication of one replay depends on the randomized
    work-stealing schedule, so bytes/critical-path are averaged over
    ``SIM_SEEDS`` independent schedules.
    """
    out = None
    bytes_r, msgs, crit, spans = [], [], [], []
    for seed in SIM_SEEDS:
        sess = Session(leaf_n=leaf_n, bs=bs, p=p, seed=seed)
        A, B = sess.from_dense(a), sess.from_dense(b)
        sess.simulate()                   # placements follow the build (§7)
        n_before = len(sess.graph.nodes)
        C = A.multiply(B, tau=tau)
        rep = sess.simulate(fresh_stats=True)
        bytes_r.append(sum(rep.bytes_received))
        msgs.append(sum(rep.messages_received))
        crit.append(rep.crit.length_s if rep.crit else 0.0)
        spans.append(rep.makespan)
        if out is None:
            trunc = C.truncation
            sess.flush()    # pallas-safe: chunk sizes final before demand
            out = {
                "tau": tau,
                "c_dense": C.to_dense(),  # stripped before JSON
                "error_bound": C.error_bound,
                "pruned_subtrees": trunc.pruned_subtrees,
                "pruned_leaf_pairs": trunc.pruned_leaf_pairs,
                "multiply_tasks": sess.n_multiply_tasks,
                "sim_tasks": rep.n_tasks,
                "flops": rep.total_flops,
                "comm_demand_bytes": an.task_comm_demand(sess.graph,
                                                         n_before),
                "c_nnz_blocks": C.nnz_blocks(),
            }
    out.update({
        "bytes_received": float(np.mean(bytes_r)),
        "bytes_received_per_seed": [int(x) for x in bytes_r],
        "messages": float(np.mean(msgs)),
        "critical_path_s": float(np.mean(crit)),
        "makespan_s": float(np.mean(spans)),
    })
    return out


# quadtree leaf config per pattern: the s2 family needs a deeper tree so
# spatially-distant (numerically tiny) leaf products prune as whole tasks
# — that is what converts norm pruning into *fetch* savings
LEAF_CFG = {"banded": ((32, 8), (64, 8)),
            "s2": ((16, 8), (32, 8)),
            "random": ((32, 8), (64, 8))}


def sweep(pattern: str, taus, quick: bool, p: int = 4
          ) -> tuple[list[dict], np.ndarray, np.ndarray]:
    """Returns (per-tau points, a, b) — operands ride along so check()
    never rebuilds them."""
    a, b = make_inputs(pattern, quick)
    leaf_n, bs = LEAF_CFG[pattern][0 if quick else 1]
    points = []
    exact = None
    for tau in taus:
        pt = run_point(a, b, tau, leaf_n=leaf_n, bs=bs, p=p)
        if tau == 0.0:
            exact = pt["c_dense"]
        err = float(np.linalg.norm(exact - pt.pop("c_dense")))
        pt["measured_error"] = err
        points.append(pt)
        print(f"{pattern},tau={tau:g},tasks={pt['sim_tasks']},"
              f"flops={pt['flops']:.4g},MB={pt['bytes_received'] / 1e6:.3f},"
              f"crit_ms={pt['critical_path_s'] * 1e3:.2f},"
              f"err={err:.3e},bound={pt['error_bound']:.3e}", flush=True)
    return points, a, b


def check(pattern: str, points: list[dict], a: np.ndarray, b: np.ndarray
          ) -> dict:
    """The acceptance contract; raises AssertionError on violation."""
    # float-rounding slack: the truncated leaf path sums block products in
    # a different order than the exact einsum, so a tau that prunes
    # nothing can still differ by O(eps * ||A|| ||B||)
    slack = 1e-9 * math.sqrt(float((a * a).sum()) * float((b * b).sum()))
    for pt in points:
        assert pt["measured_error"] <= pt["error_bound"] + slack, (
            f"{pattern} tau={pt['tau']}: measured {pt['measured_error']} "
            f"> bound {pt['error_bound']}")
    flops = [pt["flops"] for pt in points]
    tasks = [pt["sim_tasks"] for pt in points]
    demand = [pt["comm_demand_bytes"] for pt in points]
    bytes_ = [pt["bytes_received"] for pt in points]
    crit = [pt["critical_path_s"] for pt in points]
    # graph-side quantities are deterministic and provably monotone:
    # the pruned-pair set only grows with tau
    assert an.is_monotone_nonincreasing(flops), \
        f"{pattern}: flops not monotone in tau: {flops}"
    assert an.is_monotone_nonincreasing(tasks), \
        f"{pattern}: task count not monotone in tau: {tasks}"
    assert an.is_monotone_nonincreasing(demand), \
        f"{pattern}: comm demand not monotone in tau: {demand}"
    # one replay's received bytes ride on the randomized work-stealing
    # schedule: barely-pruning taus sit inside schedule noise, so the
    # replayed series only gets a loose no-regression band; the *visible*
    # reduction is asserted at the endpoints below
    assert an.is_monotone_nonincreasing(bytes_, rtol=0.25), \
        f"{pattern}: replayed bytes grew beyond schedule noise: {bytes_}"
    reduced = {
        "flops": flops[-1] / flops[0] if flops[0] else 1.0,
        "comm_demand": demand[-1] / demand[0] if demand[0] else 1.0,
        "bytes": bytes_[-1] / bytes_[0] if bytes_[0] else 1.0,
        "tasks": tasks[-1] / tasks[0] if tasks[0] else 1.0,
        "critical_path": crit[-1] / crit[0] if crit[0] else 1.0,
    }
    # the sweep must *visibly* prune on the decay families
    if pattern in ("banded", "s2"):
        assert reduced["flops"] < 0.9, \
            f"{pattern}: largest tau pruned <10% of flops ({reduced})"
        assert reduced["comm_demand"] < 0.9, \
            f"{pattern}: largest tau pruned <10% of comm demand ({reduced})"
        assert reduced["bytes"] < 0.95, \
            f"{pattern}: largest tau pruned <5% of replayed comm ({reduced})"
    return reduced


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweep (CI / perf trajectory)")
    ap.add_argument("--out", type=pathlib.Path, default=None,
                    help="write JSON record to this path")
    ap.add_argument("--patterns", nargs="+",
                    default=["banded", "s2", "random"],
                    choices=["banded", "s2", "random"])
    args = ap.parse_args()

    taus = TAUS_QUICK if args.quick else TAUS
    print("pattern,tau,tasks,flops,MB,crit_ms,err,bound")
    curves = {}
    for pattern in args.patterns:
        points, a, b = sweep(pattern, taus, args.quick)
        reduced = check(pattern, points, a, b)
        curves[pattern] = {
            "points": points,
            "reduction_at_max_tau": reduced,
            # the two headline curves: error (x) vs cost (y)
            "flops_vs_error": [[pt["measured_error"], pt["flops"]]
                               for pt in points],
            "comm_vs_error": [[pt["measured_error"], pt["bytes_received"]]
                              for pt in points],
            "comm_demand_vs_error": [[pt["measured_error"],
                                      pt["comm_demand_bytes"]]
                                     for pt in points],
        }
        print(f"{pattern}: reduction at tau={taus[-1]:g}: "
              f"flops x{reduced['flops']:.3f}, bytes x{reduced['bytes']:.3f},"
              f" tasks x{reduced['tasks']:.3f}", flush=True)

    if args.out:
        write_artifact(
            args.out, "truncation",
            {"quick": args.quick, "taus": list(taus), "curves": curves,
             "asserts": {"error_le_bound": True, "flops_monotone": True,
                         "tasks_monotone": True,
                         "comm_demand_monotone": True,
                         "replayed_bytes_rtol": 0.25}},
            params={"quick": args.quick, "taus": list(taus),
                    "patterns": args.patterns})
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
