"""Run every benchmark (one per paper table/figure) as a subprocess.

Subprocess isolation lets each benchmark own its jax/XLA configuration
(bench_tpu_comm needs virtual devices; the others want the default
single-device CPU) and makes one failure non-fatal to the rest.

``--quick`` runs only the runtime-simulator communication sweep at reduced
size and writes ``BENCH_comm_scaling.json`` at the repo root — the perf
trajectory artifact CI tracks.  The full run refreshes the same file from
the full-size sweep.
"""
import argparse
import pathlib
import subprocess
import sys
import time

BENCHES = [
    ("bench_task_counts", [],
     "Figs 3-4: task counts per level vs bounds"),
    ("bench_comm_scaling", ["--out", "BENCH_comm_scaling.json"],
     "Table 1/Figs 12-13: weak-scaling comm/process"),
    ("bench_batched_gemm", [],
     "Table 2: batched GEMM throughput vs blocksize"),
    ("bench_leaf_multiply", [],
     "Figs 5-8: leaf multiply vs fill factor"),
    ("bench_weak_scaling", [],
     "Fig 9: weak scaling + symmetric-square speedup"),
    ("bench_s2_overlap", [],
     "Figs 10-11: S^2 on 3-D overlap matrices"),
    ("bench_tpu_comm", [],
     "Fig 14: HLO collective bytes, halo vs SpSUMMA"),
    ("bench_mesh_comm", ["--out", "BENCH_mesh_comm.json"],
     "Table 1 on the mesh executor: measured fetch vs SpSUMMA"),
    ("bench_truncation", ["--out", "BENCH_truncation.json"],
     "SpAMM truncated multiply: flops/comm-vs-error tau sweep"),
    ("bench_expr_reuse", ["--out", "BENCH_expr_reuse.json"],
     "compiled-Plan reuse: flat purification iterations, <5% overhead"),
    ("bench_profile_overhead", ["--out", "BENCH_profile_overhead.json"],
     "tracing overhead guard: <3% traced, ~0% no-op"),
    ("bench_serve", ["--out", "BENCH_serve.json"],
     "plan serving: req/s vs coalesced batch size, p50/p95/p99, hit rate"),
    ("bench_fault", ["--out", "BENCH_fault.json"],
     "fault recovery: failure rate x policy, lineage beats full re-run"),
    ("bench_solvers", ["--out", "BENCH_solvers.json"],
     "solver suite: factorization methods + accuracy-scaled tau chains"),
]

QUICK = [
    ("bench_comm_scaling", ["--quick", "--out", "BENCH_comm_scaling.json"],
     "quick runtime-simulator comm sweep (perf trajectory)"),
    ("bench_truncation", ["--quick", "--out", "BENCH_truncation.json"],
     "quick truncated-multiply tau sweep (error-vs-cost trajectory)"),
    ("bench_expr_reuse", ["--quick", "--out", "BENCH_expr_reuse.json"],
     "quick compiled-Plan reuse sweep (flat-iteration + overhead guard)"),
    ("bench_mesh_comm", ["--quick", "--out", "BENCH_mesh_comm.json"],
     "quick mesh-executor fetch-volume sweep (Table-1 shape guard)"),
    ("bench_profile_overhead",
     ["--quick", "--out", "BENCH_profile_overhead.json"],
     "quick tracing overhead guard (<3% traced, ~0% no-op)"),
    ("bench_serve", ["--quick", "--out", "BENCH_serve.json"],
     "quick serving sweep (hit rate, coalesced throughput, tail latency)"),
    ("bench_fault", ["--quick", "--out", "BENCH_fault.json"],
     "quick fault-recovery sweep (degradation + recompute-subset guards)"),
    ("bench_solvers", ["--quick", "--out", "BENCH_solvers.json"],
     "quick solver sweep (factor-method + chain-target guards)"),
]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="only the reduced simulator sweep (CI-sized)")
    ap.add_argument("--only", action="append", default=None,
                    metavar="BENCH",
                    help="run only the named benchmark(s); repeatable, "
                         "matches with or without the bench_ prefix")
    args = ap.parse_args()

    root = pathlib.Path(__file__).parents[1]
    benches = QUICK if args.quick else BENCHES
    if args.only:
        wanted = {w if w.startswith("bench_") else f"bench_{w}"
                  for w in args.only}
        unknown = wanted - {name for name, _, _ in benches}
        if unknown:
            ap.error(f"unknown benchmark(s) {sorted(unknown)}; choose from "
                     f"{sorted(name for name, _, _ in benches)}")
        benches = [b for b in benches if b[0] in wanted]
    failures = []
    for name, extra, desc in benches:
        print(f"\n=== {name} — {desc} ===", flush=True)
        t0 = time.time()
        res = subprocess.run(
            [sys.executable, "-m", f"benchmarks.{name}", *extra],
            cwd=root, text=True, timeout=3600)
        dt = time.time() - t0
        status = "ok" if res.returncode == 0 else "FAILED"
        print(f"=== {name}: {status} in {dt:.0f}s ===", flush=True)
        if res.returncode:
            failures.append(name)
    if failures:
        print(f"\nFAILED benchmarks: {failures}")
        return 1
    print("\nall benchmarks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
