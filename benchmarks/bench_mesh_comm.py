"""Table 1 shape on the *executing* mesh engine: measured fetch volume.

Weak scaling (N proportional to p) of a banded quadtree multiply through
``Session(engine="mesh")`` — the parent-worker placement promoted to a
real device-sharded executor (launch/mesh_exec.py).  The reported metric
is the worst per-device **fetched bytes counter of the executor itself**
(blocks actually shipped between devices by the ring collectives, counted
once per resident block) — measured communication, not the simulator's
cost model and not parsed HLO.

The comparison target is the SpSUMMA baseline at the same weak-scaling
sizes, whose per-device slab all_gather volume is parsed from the
compiled SPMD module (the roofline methodology; SpSUMMA's traffic is
uniform by construction so the HLO number *is* the per-device number).

Expected Table-1 shape: parent-worker stays roughly flat with p on a
banded (local) pattern; SpSUMMA grows ~sqrt(p).

Runs itself in subprocesses (device count must be set before jax init).
Writes ``BENCH_mesh_comm.json`` at the repo root (or ``--out``).
"""
import argparse
import json
import os
import pathlib
import subprocess
import sys

try:
    from benchmarks._artifact import write_artifact
except ImportError:                     # run directly from benchmarks/
    from _artifact import write_artifact

_CHILD = "_child"
#: env var naming the path the mesh child writes its Perfetto trace to
_TRACE_ENV = "BENCH_MESH_TRACE"

MESH_PS = (2, 4, 8)
SUMMA_PS = (4, 16)


def child(scheme: str, p: int, n: int) -> None:
    import numpy as np

    bs = 8
    if scheme == "mesh":
        from repro import Session
        from repro.core.patterns import banded_mask, values_for_mask
        from repro.launch.mesh_exec import MeshEngine

        a = values_for_mask(banded_mask(n, 12), seed=1)
        b = values_for_mask(banded_mask(n, 7), seed=2)
        sess = Session(engine=MeshEngine(n_dev=p), leaf_n=32, bs=bs)
        A, B = sess.from_dense(a), sess.from_dense(b)
        C = A @ B
        np.testing.assert_allclose(C.to_dense(), a @ b, atol=1e-3)
        st = sess.engine_stats()
        trace_out = os.environ.get(_TRACE_ENV)
        if trace_out:
            from repro.obs import mesh_stats_events, write_chrome_trace
            write_chrome_trace(trace_out, mesh_stats_events(st))
        rec = {
            "scheme": "mesh", "p": p, "n": n,
            "max_fetched_bytes_per_dev": max(st["fetched_bytes"]),
            "sum_fetched_blocks": sum(st["fetched_blocks"]),
            "max_pushed_bytes_per_dev": max(st["pushed_bytes"]),
            "max_collective_bytes_per_dev": max(st["collective_bytes"]),
            "waves": st["waves"],
        }
    else:
        import jax
        import jax.numpy as jnp
        from repro.core import spsumma
        from repro.core.patterns import (banded_mask,
                                         block_mask_from_element_mask,
                                         values_for_mask)
        from repro.launch import roofline

        a = values_for_mask(banded_mask(n, 12), seed=1).astype(np.float32)
        ma = block_mask_from_element_mask(np.abs(a) > 0, bs)
        pg = spsumma.summa_pgrid(p)
        sp = spsumma.plan_summa(ma, ma, bs, pg)
        ab, ar, ac = spsumma.distribute_panels(a, bs, sp)
        mesh = jax.make_mesh((pg, pg), ("pr", "pc"))

        def run(*xs):
            return spsumma.summa_spmm(mesh, ("pr", "pc"), sp, *xs)

        args = [jnp.asarray(x) for x in (ab, ar, ac, ab, ar, ac)]
        compiled = jax.jit(run).lower(*args).compile()
        rec = {
            "scheme": "summa", "p": p, "n": n,
            "coll_bytes_per_dev": roofline.collective_bytes(
                compiled.as_text()),
            "pgrid": pg,
        }
    print("JSON " + json.dumps(rec))


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller weak-scaling sizes (CI)")
    ap.add_argument("--out", default="BENCH_mesh_comm.json")
    args = ap.parse_args()

    scale = 64 if args.quick else 128
    runs = [("mesh", p, scale * p) for p in MESH_PS] + \
           [("summa", p, scale * p) for p in SUMMA_PS]
    records = []
    root = pathlib.Path(__file__).parents[1]
    for scheme, p, n in runs:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p}"
        if scheme == "mesh" and p == max(MESH_PS):
            # largest mesh run also emits its per-wave device trace
            env[_TRACE_ENV] = str(root / "mesh_comm.trace.json")
        res = subprocess.run(
            [sys.executable, __file__, _CHILD, scheme, str(p), str(n)],
            capture_output=True, text=True, env=env, timeout=1800)
        if res.returncode:
            print(f"{scheme} p={p} n={n} FAILED:\n{res.stderr[-500:]}")
            return 1
        line = [l for l in res.stdout.splitlines()
                if l.startswith("JSON ")][-1]
        rec = json.loads(line[5:])
        records.append(rec)
        print(rec, flush=True)

    mesh = {r["p"]: r for r in records if r["scheme"] == "mesh"}
    summa = {r["p"]: r for r in records if r["scheme"] == "summa"}
    lo, hi = min(MESH_PS), max(MESH_PS)
    f_lo = max(1, mesh[lo]["max_fetched_bytes_per_dev"])
    f_hi = mesh[hi]["max_fetched_bytes_per_dev"]
    mesh_growth = f_hi / f_lo
    s_lo, s_hi = min(SUMMA_PS), max(SUMMA_PS)
    summa_growth = (summa[s_hi]["coll_bytes_per_dev"]
                    / max(1, summa[s_lo]["coll_bytes_per_dev"]))
    out = {
        "metric": "max per-device fetched bytes (mesh engine counters) "
                  "vs per-device HLO collective bytes (SpSUMMA)",
        "quick": bool(args.quick),
        "records": records,
        "mesh_fetch_growth_2_to_8": mesh_growth,
        "flat_2_to_8": mesh_growth <= 2.0,
        "summa_coll_growth_4_to_16": summa_growth,
    }
    path = write_artifact(
        root / args.out, "mesh_comm", out,
        params={"quick": bool(args.quick), "scale": scale, "bs": 8,
                "leaf_n": 32, "mesh_ps": list(MESH_PS),
                "summa_ps": list(SUMMA_PS)})
    print(f"\nparent-worker fetch growth {lo}->{hi} devs: "
          f"{mesh_growth:.2f}x (flat within 2x: {out['flat_2_to_8']})")
    print(f"SpSUMMA collective growth {s_lo}->{s_hi} devs: "
          f"{summa_growth:.2f}x")
    print(f"wrote {path}")
    return 0 if out["flat_2_to_8"] else 1


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == _CHILD:
        child(sys.argv[2], int(sys.argv[3]), int(sys.argv[4]))
    else:
        sys.exit(main())
