"""Compiled-plan reuse: per-iteration cost of a purification-style loop.

The api_redesign's performance contract (DESIGN.md §6), asserted here and
tracked as a CI artifact:

1. **Flat iterations** — re-running a compiled :class:`repro.Plan`
   registers *zero* new tasks, keeps the task graph and simulated
   per-iteration task count constant, and its per-iteration wall time
   does not grow with the iteration index (no hidden accumulation).
2. **Cheap compilation** — the one-time cost of building + executing a
   plan (lazy session, ``compile`` + first ``run``) stays within 5% of
   the eager single-shot facade computing the same product (min-of-N
   timings, alternating order, as in bench_task_counts).

Writes ``BENCH_expr_reuse.json`` at the repo root (``--out``); ``--quick``
shrinks sizes for CI.
"""
import argparse
import json
import pathlib
import time

import numpy as np

try:
    from benchmarks._artifact import write_artifact
except ImportError:                     # run directly from benchmarks/
    from _artifact import write_artifact


def _operand(n: int, seed: int = 0, rate: float = 6.0) -> np.ndarray:
    """Full-support decayed operand: structure closed under products."""
    rng = np.random.default_rng(seed)
    idx = np.arange(n)
    decay = np.exp(-np.abs(idx[:, None] - idx[None, :]) / rate)
    return rng.standard_normal((n, n)) * 0.1 * decay


def bench_reuse(n: int, leaf_n: int, bs: int, iters: int) -> dict:
    """The purification-loop sweep: one plan, many rebound replays."""
    from repro import Session

    a = _operand(n)
    sess = Session(lazy=True, leaf_n=leaf_n, bs=bs)
    X = sess.from_dense(a, name="X")

    t0 = time.perf_counter()
    plan = sess.compile(X @ X)
    Y = plan.run()
    t_first = time.perf_counter() - t0

    graph_sizes, times = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        Y = plan.run(X=Y)
        times.append(time.perf_counter() - t0)
        graph_sizes.append(len(sess.graph.nodes))

    assert len(set(graph_sizes)) == 1, \
        f"task graph grew across replays: {graph_sizes}"
    third = max(1, iters // 3)
    head = sorted(times[:third])[third // 2]
    tail = sorted(times[-third:])[third // 2]
    assert tail <= 3.0 * head, \
        f"per-iteration time grew: head median {head:.2e}s " \
        f"-> tail median {tail:.2e}s"

    return {
        "n": n, "leaf_n": leaf_n, "bs": bs, "iters": iters,
        "plan_tasks": plan.n_tasks,
        "graph_nodes": graph_sizes[-1],
        "first_run_s": t_first,
        "replay_s": times,
        "replay_median_s": sorted(times)[len(times) // 2],
        "head_median_s": head, "tail_median_s": tail,
    }


def bench_overhead(n: int, d: int, leaf_n: int, bs: int, repeats: int
                   ) -> dict:
    """Compiled-plan single shot vs the eager facade, min-of-N.

    Uses a banded operand at bench_task_counts' facade-overhead shape so
    the wall time is dominated by task registration (the machinery whose
    overhead is being asserted), not by leaf BLAS work whose run-to-run
    variance would swamp a few-percent difference.
    """
    from repro import Session
    from repro.core.patterns import banded_mask, values_for_mask

    a = values_for_mask(banded_mask(n, d), seed=1)

    def eager():
        sess = Session(leaf_n=leaf_n, bs=bs)
        A = sess.from_dense(a)
        _ = A @ A
        return sess

    def compiled():
        sess = Session(lazy=True, leaf_n=leaf_n, bs=bs)
        X = sess.from_dense(a, name="X")
        sess.compile(X @ X).run()
        return sess

    # identical task program (the pinned-identity guarantee)
    assert eager().task_counts() == compiled().task_counts()

    times = {"eager": [], "compiled": []}
    pair = (("eager", eager), ("compiled", compiled))
    for r in range(repeats):
        # alternate order per repeat so drift hits both sides equally
        for name, fn in (pair if r % 2 == 0 else pair[::-1]):
            t0 = time.perf_counter()
            fn()
            times[name].append(time.perf_counter() - t0)
    t_eager = min(times["eager"])
    t_compiled = min(times["compiled"])
    # two estimators of the systematic cost: the ratio of min-of-N floors,
    # and the median of per-repeat ratios (each pair runs back-to-back, so
    # coarse machine-noise modes hit both sides of a pair together).  The
    # guard takes the smaller: a real overhead shifts both, a one-sided
    # noise burst only one.
    ratios = sorted(c / e for c, e in zip(times["compiled"],
                                          times["eager"]))
    med_pair = ratios[len(ratios) // 2]
    return {
        "n": n, "d": d, "leaf_n": leaf_n, "bs": bs, "repeats": repeats,
        "eager_s": t_eager, "compiled_s": t_compiled,
        "overhead_min": t_compiled / t_eager - 1.0,
        "overhead_median_pair": med_pair - 1.0,
        "overhead": min(t_compiled / t_eager, med_pair) - 1.0,
        "eager_s_all": times["eager"], "compiled_s_all": times["compiled"],
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized: smaller matrix, fewer repeats")
    ap.add_argument("--out", type=pathlib.Path,
                    default=pathlib.Path("BENCH_expr_reuse.json"))
    args = ap.parse_args()

    # the overhead guard always runs at the bench_task_counts facade
    # shape (n=1024, d=48): per-call work large enough that min-of-N
    # converges to the true floor on noisy shared machines
    n_ov, d_ov = 1024, 48
    if args.quick:
        n, leaf_n, bs, iters, repeats = 256, 64, 8, 8, 21
    else:
        n, leaf_n, bs, iters, repeats = 512, 64, 8, 12, 25

    rec = {
        "reuse": bench_reuse(n, leaf_n, bs, iters),
        "overhead": bench_overhead(n_ov, d_ov, leaf_n, bs, repeats),
    }
    printable = dict(rec, overhead={k: v for k, v
                                    in rec["overhead"].items()
                                    if not k.endswith("_all")})
    print(json.dumps(printable, indent=1, sort_keys=True))
    write_artifact(args.out, "expr_reuse", rec,
                   params={"quick": args.quick, "n": n, "leaf_n": leaf_n,
                           "bs": bs, "iters": iters, "repeats": repeats,
                           "n_overhead": n_ov, "d_overhead": d_ov})
    print(f"wrote {args.out}")

    ov = rec["overhead"]["overhead"]
    assert ov < 0.05, \
        f"compiled-plan single shot adds {ov * 100:.1f}% over the eager " \
        f"facade (budget: 5%)"
    first = rec["reuse"]["first_run_s"]
    replay = rec["reuse"]["replay_median_s"]
    print(f"plan reuse: first run {first * 1e3:.1f} ms, replay median "
          f"{replay * 1e3:.1f} ms ({first / max(replay, 1e-12):.1f}x), "
          f"overhead vs eager {ov * 100:+.1f}%")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
